"""SO(3) machinery for eSCN-style equivariant networks.

* real spherical harmonics up to l_max (associated-Legendre recursion),
* real Wigner-D rotation blocks via the Ivanic–Ruedenberg recursion
  (J. Phys. Chem. 1996 + 1998 erratum) — D¹ is the rotation itself in the
  (y, z, x) real-SH ordering; higher degrees are built recursively, fully
  vectorized over edges,
* ``rotation_to_z`` — the per-edge frame used by the eSCN trick.

Conventions are validated by tests: orthogonality, composition
D(R₁R₂)=D(R₁)D(R₂), and the action property Y(R·r) = D(R)·Y(r).
"""
from __future__ import annotations

import math
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ real SH
def real_sph_harm(vecs: jax.Array, l_max: int) -> jax.Array:
    """vecs (..., 3) unit vectors -> (..., (l_max+1)^2) real SH values.

    Ordering: blocks of m = -l..l per degree.  Normalization: orthonormal
    (∫ Y² = 1).  Cartesian convention: θ polar from +z, φ azimuth from +x.
    """
    x, y, z = vecs[..., 0], vecs[..., 1], vecs[..., 2]
    ct = jnp.clip(z, -1.0, 1.0)
    st = jnp.sqrt(jnp.clip(1.0 - ct * ct, 0.0, None))
    phi = jnp.arctan2(y, x)

    # associated Legendre P_l^m(ct) via stable recursion
    P = {}
    P[(0, 0)] = jnp.ones_like(ct)
    for m in range(1, l_max + 1):
        P[(m, m)] = -(2 * m - 1) * st * P[(m - 1, m - 1)]
    for m in range(0, l_max):
        P[(m + 1, m)] = (2 * m + 1) * ct * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * ct * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)

    out = []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            am = abs(m)
            N = math.sqrt((2 * l + 1) / (4 * math.pi)
                          * math.factorial(l - am) / math.factorial(l + am))
            # (-1)^m cancels the Condon–Shortley phase carried by P_l^m,
            # matching the standard real-SH convention (Y_{1,-1} ∝ +y).
            cs = (-1.0) ** am
            if m == 0:
                out.append(N * P[(l, 0)])
            elif m > 0:
                out.append(cs * math.sqrt(2.0) * N * P[(l, m)]
                           * jnp.cos(m * phi))
            else:
                out.append(cs * math.sqrt(2.0) * N * P[(l, am)]
                           * jnp.sin(am * phi))
    return jnp.stack(out, axis=-1)


# ------------------------------------------------------------------ Wigner-D
def wigner_blocks(R: jax.Array, l_max: int) -> List[jax.Array]:
    """R (..., 3, 3) rotation matrices -> [D^0, D^1, ..., D^l_max] with
    D^l shaped (..., 2l+1, 2l+1), real-SH basis (m = -l..l)."""
    batch = R.shape[:-2]
    # real-SH m=(-1,0,1) basis corresponds to Cartesian (y, z, x)
    perm = np.array([1, 2, 0])
    D1 = R[..., perm, :][..., :, perm]
    blocks = [jnp.ones(batch + (1, 1), R.dtype), D1]

    def d1(i, j):  # i, j in {-1, 0, 1}
        return D1[..., i + 1, j + 1]

    for l in range(2, l_max + 1):
        Dp = blocks[-1]       # (..., 2l-1, 2l-1)

        def dp(mu, m):        # mu, m in [-(l-1), l-1]
            return Dp[..., mu + l - 1, m + l - 1]

        def Pf(i, mu, m):
            if abs(m) < l:
                return d1(i, 0) * dp(mu, m)
            if m == l:
                return d1(i, 1) * dp(mu, l - 1) - d1(i, -1) * dp(mu, -(l - 1))
            return d1(i, 1) * dp(mu, -(l - 1)) + d1(i, -1) * dp(mu, l - 1)

        rows = []
        for mp in range(-l, l + 1):
            row = []
            for m in range(-l, l + 1):
                denom = (l + m) * (l - m) if abs(m) < l else (2 * l) * (2 * l - 1)
                u = math.sqrt((l + mp) * (l - mp) / denom)
                v = 0.5 * math.sqrt((1.0 + (mp == 0)) * (l + abs(mp) - 1)
                                    * (l + abs(mp)) / denom) \
                    * (1.0 - 2.0 * (mp == 0))
                w = -0.5 * math.sqrt((l - abs(mp) - 1) * (l - abs(mp))
                                     / denom) * (1.0 - (mp == 0))
                terms = 0.0
                if u != 0.0:
                    terms = terms + u * Pf(0, mp, m)
                if v != 0.0:
                    if mp == 0:
                        V = Pf(1, 1, m) + Pf(-1, -1, m)
                    elif mp > 0:
                        V = (Pf(1, mp - 1, m) * math.sqrt(1.0 + (mp == 1))
                             - Pf(-1, -mp + 1, m) * (1.0 - (mp == 1)))
                    else:
                        V = (Pf(1, mp + 1, m) * (1.0 - (mp == -1))
                             + Pf(-1, -mp - 1, m) * math.sqrt(1.0 + (mp == -1)))
                    terms = terms + v * V
                if w != 0.0:
                    if mp > 0:
                        W = Pf(1, mp + 1, m) + Pf(-1, -mp - 1, m)
                    else:
                        W = Pf(1, mp - 1, m) - Pf(-1, -mp + 1, m)
                    terms = terms + w * W
                row.append(terms)
            rows.append(jnp.stack(row, axis=-1))
        blocks.append(jnp.stack(rows, axis=-2))
    return blocks


def apply_blocks(blocks: List[jax.Array], feats: jax.Array,
                 transpose: bool = False) -> jax.Array:
    """Apply block-diagonal Wigner-D to irreps features.

    blocks[l] (..., 2l+1, 2l+1); feats (..., lsq, C) with lsq = (l_max+1)².
    """
    outs = []
    off = 0
    for l, D in enumerate(blocks):
        n = 2 * l + 1
        blk = feats[..., off:off + n, :]
        if transpose:
            outs.append(jnp.einsum("...ji,...jc->...ic", D, blk))
        else:
            outs.append(jnp.einsum("...ij,...jc->...ic", D, blk))
        off += n
    return jnp.concatenate(outs, axis=-2)


def rotation_to_z(vec: jax.Array, eps: float = 1e-9) -> jax.Array:
    """vec (..., 3) unit vectors -> R (..., 3, 3) with R @ vec = ẑ.

    Rodrigues rotation about axis = vec × ẑ; the ±ẑ singularities fall back
    to identity / rotation about x̂ by π.
    """
    v = vec / jnp.maximum(jnp.linalg.norm(vec, axis=-1, keepdims=True), eps)
    z = jnp.zeros_like(v).at[..., 2].set(1.0)
    axis = jnp.cross(v, z)
    s = jnp.linalg.norm(axis, axis=-1, keepdims=True)           # sinθ
    c = v[..., 2:3]                                             # cosθ
    k = axis / jnp.maximum(s, eps)
    K = jnp.stack([
        jnp.stack([jnp.zeros_like(k[..., 0]), -k[..., 2], k[..., 1]], -1),
        jnp.stack([k[..., 2], jnp.zeros_like(k[..., 0]), -k[..., 0]], -1),
        jnp.stack([-k[..., 1], k[..., 0], jnp.zeros_like(k[..., 0])], -1),
    ], -2)
    eye = jnp.broadcast_to(jnp.eye(3, dtype=vec.dtype), K.shape)
    R = eye + s[..., None] * K + (1.0 - c[..., None]) * (K @ K)
    # v ≈ -ẑ: rotate π about x̂;  v ≈ +ẑ: identity
    flipx = jnp.asarray(np.diag([1.0, -1.0, -1.0]), vec.dtype)
    R = jnp.where((c < 1.0 - eps)[..., None], R, eye)
    R = jnp.where((c > -1.0 + eps)[..., None],
                  R, jnp.broadcast_to(flipx, K.shape))
    return R


def lsq(l_max: int) -> int:
    return (l_max + 1) ** 2


__all__ = ["real_sph_harm", "wigner_blocks", "apply_blocks", "rotation_to_z",
           "lsq"]
