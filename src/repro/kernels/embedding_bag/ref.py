"""Pure-jnp oracle for the fused EmbeddingBag kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, ids: jax.Array,
                      weights: jax.Array) -> jax.Array:
    """table (V, D); ids (N, L); weights (N, L) → (N, D) weighted sums."""
    emb = jnp.take(table, ids, axis=0).astype(jnp.float32)   # (N, L, D)
    out = jnp.sum(emb * weights[..., None], axis=1)
    return out.astype(table.dtype)


__all__ = ["embedding_bag_ref"]
