from .ops import *  # noqa
