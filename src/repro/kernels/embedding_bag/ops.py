"""jit'd wrapper for the fused EmbeddingBag kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .embedding_bag import embedding_bag_kernel
from .ref import embedding_bag_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_fused(table: jax.Array, ids: jax.Array,
                        mask: jax.Array = None, weights: jax.Array = None,
                        *, interpret: bool = True) -> jax.Array:
    """table (V, D); ids (N, L); optional mask/weights (N, L) → (N, D)."""
    N, L = ids.shape
    w = jnp.ones((N, L), jnp.float32)
    if weights is not None:
        w = w * weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    return embedding_bag_kernel(table, ids.astype(jnp.int32), w,
                                interpret=interpret)


embedding_bag_reference = embedding_bag_ref

__all__ = ["embedding_bag_fused", "embedding_bag_reference"]
