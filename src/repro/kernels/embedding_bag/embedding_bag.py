"""Pallas TPU kernel: fused EmbeddingBag (gather + weighted reduce).

The recsys hot path: ids (N, L) into a (V, D) table with per-slot weights
(mask folded in) → (N, D) sums.  JAX has no native EmbeddingBag; the jnp
path is take + segment_sum.  On TPU the win is the *scalar-prefetch grid*:
the ids live in SMEM ahead of the grid, and each (n, l) grid step DMAs
exactly one table row HBM→VMEM via the BlockSpec index_map — no (N, L, D)
gathered intermediate is ever materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, w_ref, row_ref, o_ref, acc_scr, *, L: int):
    l = pl.program_id(1)

    @pl.when(l == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    w = w_ref[0, l]
    acc_scr[...] = acc_scr[...] + row_ref[...].astype(jnp.float32) * w

    @pl.when(l == L - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def embedding_bag_kernel(table: jax.Array, ids: jax.Array,
                         weights: jax.Array, *,
                         interpret: bool = False) -> jax.Array:
    """table (V, D); ids (N, L) int32; weights (N, L) f32 → (N, D)."""
    N, L = ids.shape
    V, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N, L),
        in_specs=[
            pl.BlockSpec((1, L), lambda n, l, ids_ref: (n, 0)),      # weights
            pl.BlockSpec((1, D), lambda n, l, ids_ref: (ids_ref[n, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda n, l, ids_ref: (n, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        interpret=interpret,
    )(ids, weights, table)


__all__ = ["embedding_bag_kernel"]
