from .ops import *  # noqa
from .paged import *  # noqa
