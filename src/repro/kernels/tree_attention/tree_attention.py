"""Pallas TPU kernel: tree-verification decode attention (the Lookahead hot
spot — paper §4.2/§4.3 VA step).

One forward step scores T = 1+decoding_length draft slots against a KV cache
of S rows plus the freshly-written draft rows.  Flash-decoding style: the
kernel streams KV blocks HBM→VMEM with an online-softmax accumulator, so the
(T, S) score matrix never exists in HBM — on v5e this turns the dense-path
3× score-tensor traffic into pure KV traffic (the roofline floor).

TPU mapping (vs. the paper's A100 version):
  * grid = (B, K, S/block_s); the S axis is the innermost, sequential
    dimension, carrying (m, l, acc) scratch in VMEM across iterations,
  * q rows for one kv-head group = T·G ≤ 128·G — padded to an MXU-aligned
    row count; dh padded to a multiple of 128 lanes by ops.py,
  * the tree mask enters as a (T, S) boolean, blocked (T, block_s) per grid
    step — ancestor-closure for the draft region, causal for the cache
    region (built by ops.py / the serving layer),
  * block_s multiple of 128; masked-out blocks contribute zeros (exp(-inf)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, g: int, n_blocks: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (TG, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bs, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = mask_ref[0]                             # (T, bs) bool
    mask = jnp.repeat(mask, g, axis=0)             # (TG, bs)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]                          # (TG, 1)
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)   # (TG, bs)
    alpha = jnp.exp(m_prev - m_new)                # (TG, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(j == n_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def tree_attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                           mask: jax.Array, *, block_s: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q (B, K, TG, dh); k/v (B, S, K, dh); mask (B, T, S) with T = TG // G.

    Returns (B, K, TG, dh).  S must be a multiple of block_s; dh should be a
    multiple of 128 and TG a multiple of 8 (pad in ops.py).
    """
    B, K, TG, dh = q.shape
    S = k.shape[1]
    T = mask.shape[1]
    g = TG // T
    assert S % block_s == 0, (S, block_s)
    n_blocks = S // block_s
    grid = (B, K, n_blocks)
    kernel = functools.partial(_kernel, scale=dh ** -0.5, g=g,
                               n_blocks=n_blocks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, TG, dh), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_s, 1, dh), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, T, block_s), lambda b, h, j: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, TG, dh), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, TG, dh), q.dtype),
        scratch_shapes=[
            _vmem((TG, 128), jnp.float32),
            _vmem((TG, 128), jnp.float32),
            _vmem((TG, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


__all__ = ["tree_attention_grouped"]
