"""jit'd public wrapper for the tree-attention kernel.

Handles layout: (B, T, H, dh) q + (B, S, K, dh) cache → grouped
(B, K, T·G, dh), pads dh→multiple of 128 and S→multiple of block_s (padded
rows are masked out), and auto-detects the platform for interpret mode —
the compiled Mosaic kernel on TPU, the interpreter everywhere else."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .ref import tree_attention_ref
from .tree_attention import tree_attention_grouped


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def default_interpret() -> bool:
    """Pallas TPU kernels compile only on TPU; interpret elsewhere."""
    return jax.default_backend() != "tpu"


def tree_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   mask: jax.Array, *, block_s: int = 512,
                   interpret: Optional[bool] = None) -> jax.Array:
    """q (B, T, H, dh); k/v (B, S, K, dh); mask (B, T, S) → (B, T, H, dh)."""
    if interpret is None:
        interpret = default_interpret()
    return _tree_attention(q, k_cache, v_cache, mask, block_s=block_s,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def _tree_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    mask: jax.Array, *, block_s: int,
                    interpret: bool) -> jax.Array:
    B, T, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, T * G, dh)
    dh_p = -(-dh // 128) * 128
    qg = _pad_to(qg, 3, 128)
    kp = _pad_to(k_cache, 3, 128)
    vp = _pad_to(v_cache, 3, 128)
    # S not divisible by block_s: pad S up to the block multiple (padded
    # rows masked out → exp(-inf) contributes nothing) instead of collapsing
    # to a single full-S block.  bs is capped at S rounded up to the lane
    # multiple so short caches don't pad all the way to block_s.
    bs = min(block_s, -(-S // 128) * 128)
    if S % bs:
        kp = _pad_to(kp, 1, bs)
        vp = _pad_to(vp, 1, bs)
        mask = _pad_to(mask, 2, bs, value=False)
    # scale uses padded dh inside the kernel; compensate so logits match
    scale_fix = (dh_p / dh) ** 0.5
    out = tree_attention_grouped(qg * scale_fix, kp, vp, mask,
                                 block_s=bs, interpret=interpret)
    out = out[..., :dh].reshape(B, K, T, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, dh)


def tree_attention_reference(q, k_cache, v_cache, mask):
    """Oracle with the public layout."""
    B, T, H, dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, T * G, dh)
    out = tree_attention_ref(qg, k_cache, v_cache, mask)
    out = out.reshape(B, K, T, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, dh)


__all__ = ["tree_attention", "tree_attention_reference", "default_interpret"]
