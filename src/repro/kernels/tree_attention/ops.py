"""jit'd public wrapper for the tree-attention kernel.

Handles layout: (B, T, H, dh) q + (B, S, K, dh) cache → grouped
(B, K, T·G, dh), pads dh→multiple of 128 and S→multiple of block_s, and
falls back to interpret mode off-TPU (CPU validation; the TPU build uses the
compiled kernel)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import tree_attention_ref
from .tree_attention import tree_attention_grouped


def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def tree_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   mask: jax.Array, *, block_s: int = 512,
                   interpret: bool = True) -> jax.Array:
    """q (B, T, H, dh); k/v (B, S, K, dh); mask (B, T, S) → (B, T, H, dh)."""
    B, T, H, dh = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, T * G, dh)
    dh_p = -(-dh // 128) * 128
    qg = _pad_to(qg, 3, 128)
    kp = _pad_to(k_cache, 3, 128)
    vp = _pad_to(v_cache, 3, 128)
    bs = min(block_s, S) if S % min(block_s, S) == 0 else S
    sp = (-S) % bs
    if sp:
        kp = _pad_to(kp, 1, bs)
        vp = _pad_to(vp, 1, bs)
        mask = _pad_to(mask, 2, bs, value=False)
    # scale uses padded dh inside the kernel; compensate so logits match
    scale_fix = (dh_p / dh) ** 0.5
    out = tree_attention_grouped(qg * scale_fix, kp, vp, mask,
                                 block_s=bs, interpret=interpret)
    out = out[..., :dh].reshape(B, K, T, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, dh)


def tree_attention_reference(q, k_cache, v_cache, mask):
    """Oracle with the public layout."""
    B, T, H, dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, T * G, dh)
    out = tree_attention_ref(qg, k_cache, v_cache, mask)
    out = out.reshape(B, K, T, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, dh)


__all__ = ["tree_attention", "tree_attention_reference"]
