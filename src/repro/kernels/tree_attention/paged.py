"""Pallas TPU kernel: block-table (paged) tree-verification attention.

Same online-softmax structure as ``tree_attention.py``, but the KV cache is
the paged block pool ``(n_blocks, block_size, K, dh)`` shared by every lane:
the grid's innermost axis walks a lane's *logical* blocks and a scalar-
prefetched block table translates each step to the physical block the DMA
streams HBM→VMEM.  Decode therefore never materializes a contiguous
per-lane cache — the gather that the dense paged backend does with
``jnp.take`` happens inside the DMA engine's address computation instead
(PagedAttention, Kwon et al. SOSP 2023; flash-attention block-table decode).

Grid = (B, K, blocks_per_lane); the block axis is innermost/sequential and
carries (m, l, acc) scratch in VMEM.  Unallocated table entries point at the
reserved NULL block 0 — their rows are masked out, so the wasted DMA is the
only cost of fixed shapes (I2).  On TPU ``block_size`` must be a sublane
multiple (8 for f32); interpret mode (any non-TPU platform) takes any size.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ops import _pad_to, default_interpret
from .tree_attention import _kernel, _vmem


def _paged_kernel(bt_ref, q_ref, k_ref, v_ref, mask_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale, g, n_blocks):
    # the block table only steers the index maps; the body never reads it
    del bt_ref
    _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_scr, l_scr, acc_scr,
            scale=scale, g=g, n_blocks=n_blocks)


def paged_tree_attention_grouped(q: jax.Array, k: jax.Array, v: jax.Array,
                                 block_tables: jax.Array, mask: jax.Array, *,
                                 interpret: bool = False) -> jax.Array:
    """q (B, K, TG, dh); k/v (n_blocks, block_size, K, dh);
    block_tables (B, blocks_per_lane) int32; mask (B, T, S_virtual) with
    S_virtual = blocks_per_lane * block_size and T = TG // G.
    Returns (B, K, TG, dh).  dh should be a multiple of 128 (pad upstream).
    """
    from jax.experimental.pallas import tpu as pltpu

    B, K, TG, dh = q.shape
    n_blocks, bs = k.shape[0], k.shape[1]
    bpl = block_tables.shape[1]
    T = mask.shape[1]
    assert mask.shape[2] == bpl * bs, (mask.shape, bpl, bs)
    g = TG // T
    grid = (B, K, bpl)
    kernel = functools.partial(_paged_kernel, scale=dh ** -0.5, g=g,
                               n_blocks=bpl)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, TG, dh), lambda b, h, j, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda b, h, j, bt: (bt[b, j], 0,
                                                              h, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda b, h, j, bt: (bt[b, j], 0,
                                                              h, 0)),
            pl.BlockSpec((1, T, bs), lambda b, h, j, bt: (b, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, TG, dh),
                               lambda b, h, j, bt: (b, h, 0, 0)),
        scratch_shapes=[
            _vmem((TG, 128), jnp.float32),
            _vmem((TG, 128), jnp.float32),
            _vmem((TG, dh), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, TG, dh), q.dtype),
        interpret=interpret,
    )(block_tables, q, k, v, mask)


def paged_tree_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, block_tables: jax.Array,
                         mask: jax.Array, *,
                         interpret: Optional[bool] = None) -> jax.Array:
    """Public layout wrapper (mirrors ``ops.tree_attention``).

    q (B, T, H, dh); k/v (n_blocks, block_size, K, dh);
    block_tables (B, blocks_per_lane); mask (B, T, blocks_per_lane *
    block_size) → (B, T, H, dh)."""
    if interpret is None:
        interpret = default_interpret()
    return _paged_tree_attention(q, k_cache, v_cache, block_tables, mask,
                                 interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_tree_attention(q, k_cache, v_cache, block_tables, mask, *,
                          interpret: bool):
    B, T, H, dh = q.shape
    K = k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, T * G, dh)
    dh_p = -(-dh // 128) * 128
    qg = _pad_to(qg, 3, 128)
    kp = _pad_to(k_cache, 3, 128)
    vp = _pad_to(v_cache, 3, 128)
    # scale uses padded dh inside the kernel; compensate so logits match
    scale_fix = (dh_p / dh) ** 0.5
    out = paged_tree_attention_grouped(qg * scale_fix, kp, vp,
                                       block_tables.astype(jnp.int32), mask,
                                       interpret=interpret)
    out = out[..., :dh].reshape(B, K, T, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, T, H, dh)


__all__ = ["paged_tree_attention", "paged_tree_attention_grouped"]
