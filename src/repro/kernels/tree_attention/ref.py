"""Pure-jnp oracle for the tree-attention decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """q (B, K, TG, dh); k/v (B, S, K, dh); mask (B, T, S), TG = T*G.
    Returns (B, K, TG, dh) in q.dtype; softmax in f32."""
    B, K, TG, dh = q.shape
    T = mask.shape[1]
    g = TG // T
    s = jnp.einsum("bktd,bskd->bkts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    m = jnp.repeat(mask, g, axis=1)[:, None]        # (B, 1, TG, S)
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(m, p, 0.0)
    out = jnp.einsum("bkts,bskd->bktd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


__all__ = ["tree_attention_ref"]
