"""jit'd wrapper for the causal flash-prefill kernel (layout + padding).

Pads dh→multiple of 128; a ragged S (not divisible by the block sizes) is
padded up to a common block multiple — causality keeps the pad keys
invisible to every real query (their positions sit after all real rows) and
the pad query rows are sliced off the output.  Interpret mode auto-detects
the platform: compiled Mosaic kernel on TPU, interpreter elsewhere."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_prefill import flash_prefill_grouped, flash_prefill_grouped_tri
from .ref import flash_prefill_ref


def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 256, block_k: int = 512,
                  interpret: Optional[bool] = None, triangular: bool = False
                  ) -> jax.Array:
    """q (B, S, H, dh); k/v (B, S, K, dh) → causal attention (B, S, H, dh)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _flash_prefill(q, k, v, block_q=block_q, block_k=block_k,
                          interpret=interpret, triangular=triangular)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret", "triangular"))
def _flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   block_q: int, block_k: int, interpret: bool,
                   triangular: bool) -> jax.Array:
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        # ragged S: fall back to one shared block size and pad S up to it
        bq = bk = min(block_q, block_k)
        pad_s = (-S) % bq
        widths = ((0, 0), (0, pad_s), (0, 0), (0, 0))
        q = jnp.pad(q, widths)
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    S_pad = q.shape[1]
    dh_p = -(-dh // 128) * 128
    pad = dh_p - dh
    qg = q.reshape(B, S_pad, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S_pad * G, dh)
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    qg = qg * ((dh_p / dh) ** 0.5)       # kernel scales by padded dh
    if triangular:
        out = flash_prefill_grouped_tri(qg, k, v, block=min(bq, bk),
                                        interpret=interpret)
    else:
        out = flash_prefill_grouped(qg, k, v, block_q=bq, block_k=bk,
                                    interpret=interpret)
    out = out[..., :dh].reshape(B, K, S_pad, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S_pad, H, dh)[:, :S]


flash_prefill_reference = flash_prefill_ref

__all__ = ["flash_prefill", "flash_prefill_reference"]
