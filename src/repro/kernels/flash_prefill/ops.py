"""jit'd wrapper for the causal flash-prefill kernel (layout + padding)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_prefill import flash_prefill_grouped, flash_prefill_grouped_tri
from .ref import flash_prefill_ref


@functools.partial(jax.jit, static_argnames=("block_q", "block_k",
                                             "interpret", "triangular"))
def flash_prefill(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  block_q: int = 256, block_k: int = 512,
                  interpret: bool = True, triangular: bool = False
                  ) -> jax.Array:
    """q (B, S, H, dh); k/v (B, S, K, dh) → causal attention (B, S, H, dh)."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    dh_p = -(-dh // 128) * 128
    pad = dh_p - dh
    qg = q.reshape(B, S, K, G, dh).transpose(0, 2, 1, 3, 4) \
        .reshape(B, K, S * G, dh)
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pad)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    qg = qg * ((dh_p / dh) ** 0.5)       # kernel scales by padded dh
    if triangular:
        out = flash_prefill_grouped_tri(qg, k, v, block=min(bq, bk),
                                        interpret=interpret)
    else:
        out = flash_prefill_grouped(qg, k, v, block_q=bq, block_k=bk,
                                    interpret=interpret)
    out = out[..., :dh].reshape(B, K, S, G, dh).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, S, H, dh)


flash_prefill_reference = flash_prefill_ref

__all__ = ["flash_prefill", "flash_prefill_reference"]
