"""Pure-jnp oracle for causal GQA prefill attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """q (B, S, H, dh); k/v (B, S, K, dh) → (B, S, H, dh), causal."""
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    causal = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(causal, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, dh).astype(q.dtype)


__all__ = ["flash_prefill_ref"]
