from .ops import *  # noqa
