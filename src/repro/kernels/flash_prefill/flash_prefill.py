"""Pallas TPU kernel: causal flash attention for the 32k prefill path.

FlashAttention-2 style: grid = (B, K, q_blocks, kv_blocks), kv innermost and
sequential with (m, l, acc) VMEM scratch; blocks strictly above the causal
diagonal contribute nothing (masked; on real TPU the block can be skipped
with a scalar-prefetch grid, noted for the hardware build).

GQA layout: q rows grouped per kv head — (B, K, Sq·G, dh) like
tree_attention; the causal mask is derived from block indices in-kernel
(no (S, S) mask tensor ever materializes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, g: int, block_q: int, block_k: int,
            n_kv_blocks: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # (bq*G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # causal mask from absolute positions
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q * g, 1), 0) // g
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_k), 1)
    mask = q_pos >= k_pos
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kj == n_kv_blocks - 1)
    def _finish():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_prefill_grouped(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          block_q: int = 256, block_k: int = 512,
                          interpret: bool = False) -> jax.Array:
    """q (B, K, S·G, dh) grouped causal self-attention; k/v (B, S, K, dh)."""
    B, K, SG, dh = q.shape
    S = k.shape[1]
    g = SG // S
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (B, K, S // block_q, S // block_k)
    kernel = functools.partial(_kernel, scale=dh ** -0.5, g=g,
                               block_q=block_q, block_k=block_k,
                               n_kv_blocks=S // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q * g, dh),
                         lambda b, h, qi, kj: (b, h, qi, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, qi, kj: (b, kj, h, 0)),
            pl.BlockSpec((1, block_k, 1, dh),
                         lambda b, h, qi, kj: (b, kj, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * g, dh),
                               lambda b, h, qi, kj: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, SG, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 128), jnp.float32),
            pltpu.VMEM((block_q * g, 128), jnp.float32),
            pltpu.VMEM((block_q * g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def _tri_qi(t):
    """Triangular enumeration: t -> (qi, kj) with kj <= qi."""
    tf = t.astype(jnp.float32)
    qi = jnp.floor((jnp.sqrt(8.0 * tf + 1.0) - 1.0) * 0.5 + 1e-4
                   ).astype(jnp.int32)
    kj = t - qi * (qi + 1) // 2
    return qi, kj


def _kernel_tri(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, g: int, block: int):
    t = pl.program_id(2)
    qi, kj = _tri_qi(t)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = qi * block + jax.lax.broadcasted_iota(
        jnp.int32, (block * g, 1), 0) // g
    k_pos = kj * block + jax.lax.broadcasted_iota(
        jnp.int32, (1, block), 1)
    mask = q_pos >= k_pos            # only the diagonal block is partial
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, :1]
    l_prev = l_scr[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((0 + 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kj == qi)
    def _finish():
        l = jnp.maximum(l_scr[:, :1], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_prefill_grouped_tri(q: jax.Array, k: jax.Array, v: jax.Array, *,
                              block: int = 256,
                              interpret: bool = False) -> jax.Array:
    """Causal flash attention on a TRIANGULAR grid: blocks strictly above the
    diagonal are never scheduled, halving kernel FLOPs and KV traffic vs the
    rectangular grid (beyond-paper §Perf optimization for prefill_32k).
    Requires block_q == block_k == ``block``."""
    B, K, SG, dh = q.shape
    S = k.shape[1]
    g = SG // S
    assert S % block == 0, (S, block)
    nq = S // block
    n_tri = nq * (nq + 1) // 2
    grid = (B, K, n_tri)
    kernel = functools.partial(_kernel_tri, scale=dh ** -0.5, g=g,
                               block=block)

    def qmap(b, h, t):
        qi, _ = _tri_qi(t)
        return (b, h, qi, 0)

    def kmap(b, h, t):
        _, kj = _tri_qi(t)
        return (b, kj, h, 0)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block * g, dh), qmap),
            pl.BlockSpec((1, block, 1, dh), kmap),
            pl.BlockSpec((1, block, 1, dh), kmap),
        ],
        out_specs=pl.BlockSpec((1, 1, block * g, dh), qmap),
        out_shape=jax.ShapeDtypeStruct((B, K, SG, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block * g, 128), jnp.float32),
            pltpu.VMEM((block * g, 128), jnp.float32),
            pltpu.VMEM((block * g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


__all__ = ["flash_prefill_grouped", "flash_prefill_grouped_tri"]
