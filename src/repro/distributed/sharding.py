"""Logical-axis sharding rules (MaxText-style) + activation constraint hooks.

Model code annotates tensors with *logical* axis names; the launcher activates
a (mesh, rules) context and the hooks translate logical names to mesh axes.
Outside a context every hook is a no-op, so smoke tests / CPU benches run
unchanged on one device.

Mesh axes (launch/mesh.py):
  * ``pod``   — outer data parallelism across pods (2 pods = 512 chips)
  * ``data``  — FSDP / batch / sequence sharding inside a pod
  * ``model`` — tensor parallelism (heads, ffn, vocab, experts)
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> tuple of mesh axis names (applied in order)."""
    rules: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    def mesh_axes(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        return self.rules.get(logical, ())

    def override(self, **kw: Tuple[str, ...]) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(d)


DEFAULT_RULES = ShardingRules({
    # activations
    "batch":      ("pod", "data"),
    "seq":        (),                  # seq replicated by default
    "residual_seq": (),                # train cells override to ("model",)
    "kv_seq":     ("pod", "data"),     # long-context decode: KV sequence shard
    "heads":      ("model",),
    "kv_heads":   ("model",),
    "embed":      (),
    "ffn_act":    ("model",),
    "vocab_act":  ("model",),
    # weights: 2-D fsdp x tp
    "fsdp":       ("data",),
    "tensor":     ("model",),
    "expert":     ("model",),
    # graph / recsys
    "edges":      ("pod", "data", "model"),
    "nodes":      ("pod", "data"),
    "table_rows": ("model",),
    "candidates": ("pod", "data", "model"),
})


# --------------------------------------------------------------------- context
_ACTIVE: list = []


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    """Activate (mesh, rules) for `constrain` hooks inside jit traces."""
    _ACTIVE.append((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.pop()


def active_mesh() -> Optional[Mesh]:
    if _ACTIVE and _ACTIVE[-1][0] is not None:
        return _ACTIVE[-1][0]
    return None


def _active() -> Tuple[Optional[Mesh], ShardingRules]:
    if _ACTIVE:
        return _ACTIVE[-1]
    return None, DEFAULT_RULES


def logical_spec(logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None) -> P:
    """Translate per-dim logical names to a PartitionSpec.

    Mesh axes missing from the mesh are dropped; if ``shape`` is given, axes
    that do not divide the dim are dropped too (robustness for odd configs).
    """
    m, r = _active()
    mesh = mesh or m
    rules = rules or r
    spec = []
    used: set = set()
    for d, name in enumerate(logical_axes):
        axes = []
        size = 1
        for ax in rules.mesh_axes(name):
            if mesh is None or ax not in mesh.shape or ax in used:
                continue
            nsz = size * mesh.shape[ax]
            if shape is not None and shape[d] % nsz != 0:
                continue
            axes.append(ax)
            used.add(ax)
            size = nsz
        spec.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*spec)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active (mesh, rules); no-op otherwise."""
    mesh, rules = _active()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = logical_spec(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                   shape: Optional[Sequence[int]] = None,
                   rules: ShardingRules = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_spec(logical_axes, shape, mesh, rules))


def axis_size(name: str) -> int:
    """Static size of a named mesh axis inside shard_map.

    ``jax.lax.axis_size`` only exists in newer jax releases; on older ones a
    psum of the Python literal 1 takes jax's constant fast path and returns
    the axis size as a static int (shape-safe for reshapes).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


__all__ = ["ShardingRules", "DEFAULT_RULES", "sharding_ctx", "constrain",
           "active_mesh", "logical_spec", "named_sharding", "axis_size"]
