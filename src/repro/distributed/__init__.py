from .sharding import (ShardingRules, DEFAULT_RULES, sharding_ctx, constrain,
                       active_mesh, logical_spec, named_sharding)

__all__ = ["ShardingRules", "DEFAULT_RULES", "sharding_ctx", "constrain",
           "active_mesh", "logical_spec", "named_sharding"]
