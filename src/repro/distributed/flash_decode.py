"""Sequence-parallel tree-decode attention (flash-decoding style).

Axis assignment is derived from the shapes at trace time:

  * batch → (pod, data) when divisible (decode_32k: B=128);
  * KV heads / Q heads → model when divisible (phi3-mini K=32, moonshot 16);
  * otherwise the KV **sequence** absorbs the leftover axes — batch=1
    long-context decode shards S over (pod, data[, model]), and GQA archs
    whose K doesn't divide TP=16 (qwen2 K=2, phi3-medium K=10, qwen3 K=4)
    shard S over model.  Partial attention per shard is combined with the
    numerically-stable log-sum-exp trick:

      M = pmax(m_l);  S = psum(e^{m_l-M} s_l);  O = psum(e^{m_l-M} o_l)

Collective cost per layer: one pmax + two psums of (B_loc, T, H_loc, dh) —
independent of S.  This replaces either an all-gather of a multi-GB KV cache
or 16× replicated attention compute (the two naive alternatives XLA picks).
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.distributed.sharding import active_mesh, axis_size
from repro.models.layers import NEG_INF


def _derive_axes(mesh: Mesh, B: int, S: int, K: int, H: int):
    """Returns (batch_axes, seq_axes, head_axis)."""
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    tp = mesh.shape.get("model", 1)
    heads_ok = tp > 1 and K % tp == 0 and H % tp == 0
    if dp > 1 and B % dp == 0 and B >= dp:
        batch_axes, seq_dp = dp_axes, ()
    else:
        batch_axes, seq_dp = (), dp_axes
    seq_axes = tuple(seq_dp)
    if not heads_ok and tp > 1:
        seq_axes = seq_axes + ("model",)
    # drop seq sharding if not divisible
    nseq = 1
    for a in seq_axes:
        nseq *= mesh.shape[a]
    if nseq <= 1 or S % nseq != 0:
        seq_axes = ()
    head_ax = "model" if heads_ok else None
    return batch_axes, seq_axes, head_ax


def make_flash_attend(mesh: Mesh, cache_lens: jax.Array,
                      tree_mask: jax.Array, score_f32: bool = True
                      ) -> Callable:
    """Returns attend(q, k_new, v_new, k_cache, v_cache)
    -> (attn_out, k_cache, v_cache) with sharded caches."""

    def attend(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
               k_cache: jax.Array, v_cache: jax.Array):
        B, T, H, dh = q.shape
        S, K = k_cache.shape[1], k_cache.shape[2]
        batch_axes, seq_axes, h_ax = _derive_axes(mesh, B, S, K, H)
        ba = batch_axes if batch_axes else None
        sa = seq_axes if seq_axes else None

        fn = functools.partial(_local_attend, seq_axes=seq_axes,
                               T=T, scale=dh ** -0.5, score_f32=score_f32)
        out, kc, vc = shard_map(
            fn, mesh=mesh,
            in_specs=(P(ba, None, h_ax, None),      # q
                      P(ba, None, h_ax, None),      # k_new
                      P(ba, None, h_ax, None),      # v_new
                      P(ba, sa, h_ax, None),        # k_cache
                      P(ba, sa, h_ax, None),        # v_cache
                      P(ba),                        # cache_lens
                      P(ba, None, None)),           # tree_mask
            out_specs=(P(ba, None, h_ax, None),
                       P(ba, sa, h_ax, None),
                       P(ba, sa, h_ax, None)),
            check_rep=False,
        )(q, k_new, v_new, k_cache, v_cache, cache_lens, tree_mask)
        return out, kc, vc

    return attend


def cache_partition_spec(mesh: Mesh, B: int, S: int, K: int, H: int) -> P:
    """PartitionSpec for a (L, B, S, K, dh) cache consistent with attend."""
    batch_axes, seq_axes, h_ax = _derive_axes(mesh, B, S, K, H)
    return P(None, batch_axes if batch_axes else None,
             seq_axes if seq_axes else None, h_ax, None)


def _local_attend(q, k_new, v_new, k_c, v_c, cache_lens, tree_mask, *,
                  seq_axes: Tuple[str, ...], T: int, scale: float,
                  score_f32: bool = True):
    B, _, Hl, dh = q.shape
    Sl, Kl = k_c.shape[1], k_c.shape[2]
    G = Hl // Kl
    # global offset of this shard's KV rows
    idx = jnp.zeros((), jnp.int32)
    for a in seq_axes:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    offset = idx * Sl

    # scatter the new draft KV rows that land in this shard.  NB: negative
    # indices wrap (Python semantics) BEFORE mode="drop" applies — redirect
    # them to Sl, which IS out of bounds and therefore dropped.
    bidx = jnp.arange(B)[:, None]
    loc = cache_lens[:, None] + jnp.arange(T)[None, :] - offset    # (B,T)
    loc = jnp.where((loc >= 0) & (loc < Sl), loc, Sl)
    k_c = k_c.at[bidx, loc].set(k_new.astype(k_c.dtype), mode="drop")
    v_c = v_c.at[bidx, loc].set(v_new.astype(v_c.dtype), mode="drop")

    # mask over local rows
    jglob = offset + jnp.arange(Sl)
    past = jglob[None, None, :] < cache_lens[:, None, None]
    rel = jglob[None, None, :] - cache_lens[:, None, None]          # (B,1,Sl)
    relc = jnp.clip(rel, 0, T - 1).astype(jnp.int32)
    tm = jnp.take_along_axis(tree_mask,
                             jnp.broadcast_to(relc, (B, T, Sl)), axis=2)
    mask = past | ((rel >= 0) & (rel < T) & tm)                     # (B,T,Sl)

    qg = q.reshape(B, T, Kl, G, dh)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k_c,
                   preferred_element_type=jnp.float32 if score_f32
                   else q.dtype) * scale
    s = s.astype(jnp.float32)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m_l = jnp.maximum(jnp.max(s, axis=-1), -1e30)                   # (B,K,G,T)
    p = jnp.where(mask[:, None, None], jnp.exp(s - m_l[..., None]), 0.0)
    s_l = jnp.sum(p, axis=-1)
    o_l = jnp.einsum("bkgts,bskh->bkgth", p.astype(v_c.dtype), v_c
                     ).astype(jnp.float32)
    if seq_axes:
        M = jax.lax.pmax(m_l, seq_axes)
        c = jnp.exp(m_l - M)
        s_g = jax.lax.psum(s_l * c, seq_axes)
        o_g = jax.lax.psum(o_l * c[..., None], seq_axes)
    else:
        s_g, o_g = s_l, o_l
    out = o_g / jnp.maximum(s_g[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, T, Hl, dh)
    return out.astype(q.dtype), k_c, v_c


class FlashDecodeBackend:
    """Attention backend (registry name ``flash_decode``) wrapping the
    sequence-parallel shard_map decode above.

    This folds the old ``decode_attn == "flash_decode"`` special case that
    lived inside ``transformer.tree_step`` into the common backend
    interface (repro.models.attention).  Prefill delegates to the dense
    reference math; the decode phase uses the sharded path whenever a mesh
    is active and otherwise degrades to dense — identical semantics, no
    shard_map.  Imports of the registry module are deferred to call time
    (attention.py imports this module to register the backend).
    """

    name = "flash_decode"

    def prefill_attention(self, cfg, q, k, v, positions, len_mask):
        from repro.models.attention import dense_prefill_attention
        return dense_prefill_attention(cfg, q, k, v, positions, len_mask)

    def make_tree_attend(self, cfg, cache_lens, tree_mask, S_max):
        mesh = active_mesh()
        if mesh is None:
            from repro.models.attention import get_backend
            return get_backend("dense").make_tree_attend(cfg, cache_lens,
                                                         tree_mask, S_max)
        return make_flash_attend(mesh, cache_lens, tree_mask,
                                 score_f32=cfg.attn_score_f32)

    def make_paged_tree_attend(self, cfg, block_tables, cache_lens,
                               tree_mask, slot_valid=None):
        """The paged pool is lane-agnostic, so the sequence-parallel
        shard_map layout does not apply; delegate to the dense gather path
        (identical semantics, no mesh)."""
        from repro.models.attention import get_backend
        return get_backend("dense").make_paged_tree_attend(
            cfg, block_tables, cache_lens, tree_mask, slot_valid)


__all__ = ["make_flash_attend", "cache_partition_spec", "FlashDecodeBackend"]
